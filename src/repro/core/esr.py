"""Exact state reconstruction — paper Alg. 2, run on the replacement nodes.

Given the two latest redundantly-stored search directions p^(j-1), p^(j), the
replicated scalar β^(j-1), and the surviving parts of r^(j), x^(j), rebuild
the failed nodes' entries of z, r, x *exactly* (up to fp perturbation):

  line 4:  z_f = p_f^(j) − β^(j-1) p_f^(j-1)
  line 5:  v  = z_f − P_{f,I\f} r_{I\f}
  line 6:  solve P_ff r_f = v
  line 7:  w  = b_f − r_f − A_{f,I\f} x_{I\f}
  line 8:  solve A_ff x_f = w                     (inner PCG @ rtol 1e-14,
                                                   block-Jacobi precond — §5)

Lines 5-6 are *preconditioner-aware* (repro.precond): block-Jacobi keeps the
seed's exact closed forms (P offdiag ≡ 0 so v = z_f; P_ff⁻¹ = the raw
diagonal blocks so line 6 is a block matvec), while preconditioners with
genuine off-diagonal coupling (SSOR, Chebyshev, IC(0)) route through the
operators their class supplies: line 5 applies the actual P row strip to the
masked survivors, line 6 runs a real local P_ff solve whose operator
applications execute the preconditioner's kernels (triangular sweeps /
polynomial recurrence). The line-8 inner solve always uses block-Jacobi on
A_ff — a reconstruction-internal choice, independent of the hot-loop P.

Static data (A rows, P static state, b entries of the failed nodes) is
rebuilt from the problem's host-side COO — the paper's "retrieve from safe
storage".

The failed set may span several (non-contiguous) nodes — one simultaneous
multi-node event of the scenario engine resolves to ONE reconstruction over
the union I_f of all its failed rows (arXiv:1907.13077's simultaneous case);
the inner solves are zero-RHS-safe (``run_pcg`` returns x = 0, rel = 0.0
instead of NaN when a strip of v or w is exactly zero).

Where p^(j-1), p^(j) come from is the caller's business: the single-device
simulator passes the host-visible queue slots, while the sharded runtime
assembles them from the *surviving devices'* physical queue shards
(``comm.shard.ShardedFailureRuntime.assemble_pair``) — ``reconstruct`` only
ever reads the failed rows of these vectors plus the surviving rows of
r, x, so either source yields the same algebra.
"""
from __future__ import annotations

import contextlib
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import failures
from repro.core.pcg import _expand, run_pcg, run_pcg_batched
from repro.sparse.blockell import BlockEll
from repro.sparse.matrices import Problem


@dataclasses.dataclass
class ReconstructionOps:
    """Static per-(problem, failed-set) operators, built once per failure.

    In a real cluster this assembly happens on the replacement nodes from
    safe storage; it is therefore *not* part of the solver's dynamic state.
    """

    problem: Problem
    failed: list[int]
    mask: np.ndarray                 # (M,) bool over I_f
    f_rows: np.ndarray               # sorted global indices I_f
    a_rows_f: BlockEll               # A_{I_f, I}        (|I_f| x M strip)
    a_ff: BlockEll                   # A_{I_f, I_f}      (compact)
    diag_f: jax.Array                # (|I_f|/b, b, b) raw diag blocks (= P_ff^{-1})
    pinv_f: jax.Array                # (|I_f|/b, b, b) inverse blocks (A_ff precond)
    b_f: jax.Array
    precond_f: object = None         # stable closure: jitted inner solves
    #                                  must see the same callable each call
    p_offdiag: object = None         # line 5: r_surv -> P_{f,I\f} r_{I\f}
    #                                  (None = exactly zero, block-Jacobi)
    p_solve: object = None           # line 6: v -> r_f solving P_ff r_f = v
    #                                  (None = seed diag-block matvec)

    @staticmethod
    def build(problem: Problem, failed: list[int],
              pff_precond: bool = True) -> "ReconstructionOps":
        part = problem.part
        failed = sorted(set(failed))
        mask = failures.failed_row_mask(part, failed)
        f_rows = failures.failed_rows(part, failed)
        to_compact = failures.compact_map(part, failed)
        # the compact strip is re-blocked at bm granularity below (rt = nf//bm
        # truncates); a misaligned union of failed rows would silently drop
        # rows instead of failing loudly — scenario events are validated
        # upstream, but ReconstructionOps is also a public entry point
        if f_rows.size % part.bm != 0:
            raise ValueError(
                f"failed-row union ({f_rows.size} rows) is not a multiple of "
                f"the block size bm={part.bm}")

        rows, cols, vals = problem.coo
        in_f_rows = mask[rows]
        # A_{I_f, I}: compact rows, global cols
        r_sel = rows[in_f_rows]
        a_rows_f = BlockEll.from_coo(
            to_compact(r_sel), cols[in_f_rows], vals[in_f_rows],
            m=part.m, bm=part.bm, bn=part.bn, dtype=np.asarray(vals).dtype)
        # from_coo builds square-shape metadata; fix the row extent
        nf = f_rows.size
        rt = nf // part.bm
        a_rows_f = BlockEll(a_rows_f.data[:rt], a_rows_f.idx[:rt],
                            a_rows_f.nblk[:rt], (nf, part.m), part.bm, part.bn)

        in_ff = in_f_rows & mask[cols]
        a_ff = BlockEll.from_coo(
            to_compact(rows[in_ff]), to_compact(cols[in_ff]), vals[in_ff],
            m=nf, bm=part.bm, bn=part.bn, dtype=np.asarray(vals).dtype)

        b_blk = problem.precond_block
        blk_ids = np.unique(f_rows // b_blk)
        pinv_f = problem.pinv_blocks[blk_ids]

        def precond_f(r, _pinv=pinv_f, _b=b_blk):
            return jnp.einsum("nij,nj->ni", _pinv,
                              r.reshape(-1, _b)).reshape(-1)

        # recovery-aware lines 5-6: preconditioners with off-diagonal
        # coupling supply their own local operators; block-Jacobi (or a
        # legacy Problem without a precond object) keeps the seed shortcut
        # ``pff_precond`` threads to the line-6 inner CG: True runs it
        # preconditioned with the class's failed-slab-truncated operator
        # (precond.base._pff_inner_precond), False keeps the historical
        # unpreconditioned solve (the A/B the recovery microbench times)
        pc = problem.precond
        p_offdiag = p_solve = None
        if pc is not None and pc.name != "jacobi":
            p_offdiag, p_solve = pc.local_ops(mask, f_rows,
                                              pff_precond=pff_precond)

        return ReconstructionOps(
            problem=problem, failed=failed, mask=mask, f_rows=f_rows,
            a_rows_f=a_rows_f, a_ff=a_ff,
            diag_f=problem.diag_blocks[blk_ids],
            pinv_f=pinv_f,
            b_f=problem.b[f_rows], precond_f=precond_f,
            p_offdiag=p_offdiag, p_solve=p_solve)


def _span(tracer, name: str, **args):
    """Recovery-phase span, or a no-op context when observability is off."""
    if tracer is None:
        return contextlib.nullcontext()
    return tracer.span(name, cat="recovery", **args)


def reconstruct(ops: ReconstructionOps, *, p_prev: jax.Array, p_curr: jax.Array,
                beta_prev: jax.Array, r_surv: jax.Array, x_surv: jax.Array,
                inner_rtol: float = 1e-14, inner_max_iters: int = 20_000,
                b_f: jax.Array | None = None, tracer=None):
    """Run Alg. 2. Inputs are full-length vectors; only surviving (resp.
    redundant-copy) entries are read, enforced by masking. Returns the failed
    nodes' compact (x_f, r_f, z_f) plus the inner-solve relative residual.

    ``tracer`` (an obs.Tracer, or None) gets one nested span per Alg. 2
    phase — lines 4-5 (off-diagonal apply), the line-6 P_ff solve, the
    line-7 RHS assembly, the line-8 A_ff solve. Per-phase wall times are
    only meaningful with a host sync at each boundary, so the spans
    block_until_ready their segment's outputs; tracer=None skips both the
    spans and the syncs (the default async hot path is untouched).

    Batch-polymorphic: (B, M) vector inputs with (B,) ``beta_prev`` run ONE
    Alg. 2 pass over the shared f-slab for all B members — the static strip
    operators are shared, lines 4/6/7 apply the per-member-unrolled scalar
    subgraphs, and line 8 is a single batched inner solve with per-member
    freeze (``run_pcg_batched``) — so each member's reconstruction is
    bit-identical in f64 to its own B=1 pass. Batched mode requires the
    block-Jacobi closed forms (``p_solve is None``).

    ``b_f`` overrides the RHS entries baked into ``ops`` (line 7) — the
    batched driver solves B different right-hand sides against the one
    static strip set, so it passes ``rhs[:, f_rows]`` here.
    """
    sync = jax.block_until_ready if tracer is not None else (lambda x: x)
    mask = jnp.asarray(ops.mask)
    f_rows = jnp.asarray(ops.f_rows)
    b = ops.problem.precond_block
    batched = x_surv.ndim == 2
    if batched and ops.p_solve is not None:
        raise NotImplementedError(
            "batched reconstruction supports the block-Jacobi closed forms "
            "only (preconditioners with off-diagonal coupling pend)")

    itemsize = np.dtype(r_surv.dtype).itemsize
    with _span(tracer, "alg2_line5_offdiag", n_failed_rows=int(ops.f_rows.size),
               bytes=int((ops.f_rows.size + r_surv.size) * itemsize),
               jacobi_closed_form=ops.p_solve is None):
        p_prev_f = p_prev[..., f_rows]
        p_curr_f = p_curr[..., f_rows]
        z_f = p_curr_f - _expand(beta_prev, p_curr_f) * p_prev_f  # line 4
        if ops.p_solve is None:
            # block-Jacobi closed form: P_{f,I\f} == 0, so line 5 is v = z_f
            v = sync(z_f)                                       # line 5
        else:
            # genuine off-diagonal coupling: apply the real P row strip to
            # the surviving entries (the closure masks I_f)
            v = sync(z_f - ops.p_offdiag(r_surv))               # line 5

    with _span(tracer, "alg2_line6_pff_solve",
               jacobi_closed_form=ops.p_solve is None) as sp6:
        if ops.p_solve is None:
            # block-Jacobi closed form: P_ff^{-1} = A_bb, one block matvec
            # (per member when batched — keeps the scalar subgraph exact)
            def pff_mv(vi):
                return jnp.einsum("nij,nj->ni", ops.diag_f,
                                  vi.reshape(-1, b)).reshape(-1)
            r_f = sync(pff_mv(v) if not batched else
                       jnp.stack([pff_mv(v[i])
                                  for i in range(v.shape[0])]))   # line 6
        else:
            # real local P_ff solve through the preconditioner's kernels
            r_f = sync(ops.p_solve(v, inner_rtol, inner_max_iters))  # line 6
            stats = getattr(ops.p_solve, "stats", None)
            if sp6 is not None and stats:
                sp6.args.update({k: jsonable_stat(v2)
                                 for k, v2 in dict(stats).items()})

    with _span(tracer, "alg2_line7_w"):
        x_masked = jnp.where(mask, jnp.zeros_like(x_surv), x_surv)
        if not batched:
            ax = ops.a_rows_f.matvec(x_masked)
        else:
            ax = jnp.stack([ops.a_rows_f.matvec(x_masked[i])
                            for i in range(x_masked.shape[0])])
        w = sync((ops.b_f if b_f is None else b_f) - r_f - ax)     # line 7

    with _span(tracer, "alg2_line8_aff_solve",
               inner_rtol=inner_rtol) as sp8:
        solve = run_pcg if not batched else run_pcg_batched
        state, rel = solve(ops.a_ff.matvec, ops.precond_f, w,
                           inner_rtol, inner_max_iters)            # line 8
        x_f = sync(state.x)
        if sp8 is not None:
            sp8.args["inner_rel"] = (float(rel) if not batched
                                     else float(np.max(np.asarray(rel))))
            sp8.args["inner_iters"] = int(state.j)
    return x_f, r_f, z_f, rel


def jsonable_stat(v):
    """Best-effort scalar coercion for p_solve iteration stats."""
    try:
        return float(np.asarray(v).reshape(()))
    except Exception:                                  # noqa: BLE001
        return str(v)


def scatter_failed(full_surv: jax.Array, compact_f: jax.Array,
                   ops: ReconstructionOps) -> jax.Array:
    """Merge reconstructed failed entries into the surviving vector.
    Batch-polymorphic: (B, M) + (B, |I_f|) scatters per member."""
    return full_surv.at[..., jnp.asarray(ops.f_rows)].set(compact_f)
