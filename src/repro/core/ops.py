"""SolverOps — the execution layer of the PCG/ESRP/IMCR hot loop.

The paper's resilience argument (and Levonyak et al.'s scaling argument for
resilient PCG) only holds if the failure-free iteration runs as fast as the
hardware allows; constant-factor slack in the hot loop gets misread as
resilience overhead. ``SolverOps`` bundles the four operations one PCG
iteration needs —

  * ``matvec``      q = A·p                       (Block-ELL SpMV)
  * ``matvec_dot``  (q, pᵀq) in one pass          (α needs no 2nd read of p/q)
  * ``precond``     z = P r                       (block-Jacobi apply)
  * ``update``      (x', r', z', rz') fused       (Alg. 1 lines 4-7, one pass)

— so the solver core is written once against the bundle and the backend
decides how each op executes:

  * ``jnp``       reference backend: pure-jnp ops *structured like the
                  kernels* (sequential k accumulation, per-block partial
                  dots), so its f64 trajectory is bit-identical to the
                  Pallas one — the cross-backend trajectory-identity
                  property tested in tests/test_solver_ops.py.
  * ``pallas``    the TPU kernels (kernels/spmv, kernels/fused_pcg).
  * ``interpret`` the same kernels in Pallas interpret mode (CI validation).
  * closures      arbitrary (matvec, precond) pairs — dense test operators,
                  the sharded runtime, reconstruction inner solves — via
                  ``make_closure_ops``; numerics identical to the seed path.

Ops bundles are cached per (problem, backend) so the jitted chunk runners,
which treat the bundle as a static argument, compile once per backend
instead of once per ``solve_resilient`` call.
"""
from __future__ import annotations

from typing import Callable, NamedTuple


class SolverOps(NamedTuple):
    """Execution backend for one PCG iteration. Hashable (functions compare
    by identity) so jitted chunk runners can take it as a static argument."""
    backend: str
    matvec: Callable            # p -> q = A p
    matvec_dot: Callable        # p -> (q, p @ q)
    precond: Callable           # r -> z = P r
    update: Callable            # (alpha, x, r, p, q) -> (x', r', z', rz')
    variant: str = ""           # preconditioner execution variant (e.g. the
    #                             sharded runtime's "node-local ssor")
    dot: Callable | None = None  # (u, v) -> uᵀv with this backend's reduction
    #                             structure; None = plain u @ v. Off-hot-loop
    #                             dots (pcg_init's r₀ᵀz₀, the residual-
    #                             replacement rᵀz) route through it so the
    #                             sharded runtime and its single-device
    #                             mesh-mirror stay bit-identical in f64.


def make_closure_ops(matvec: Callable, precond: Callable) -> SolverOps:
    """Wrap arbitrary (matvec, precond) closures. The update is the seed's
    unfused op sequence, so trajectories through this bundle are bit-identical
    to the pre-SolverOps code path. Callers that solve repeatedly should hold
    on to the returned bundle (the driver caches it on the Problem): the
    jitted chunk runners key their compile cache on it."""

    def matvec_dot(p):
        q = matvec(p)
        return q, p @ q

    def update(alpha, x, r, p, q):
        x_new = x + alpha * p
        r_new = r - alpha * q
        z_new = precond(r_new)
        return x_new, r_new, z_new, r_new @ z_new

    return SolverOps("closure", matvec, matvec_dot, precond, update)


def pick_rows(m: int, block: int, target: int = 512) -> int:
    """Per-grid-step row-block length for the fused update: the largest
    multiple of the preconditioner block that divides M and is <= target
    (TPU wants a multiple of the lane width; the divisibility constraint
    dominates on the padded test grids)."""
    if m % block:
        raise ValueError(f"M={m} not divisible by precond block {block}")
    best = block
    for d in range(1, m // block + 1):
        rows = block * d
        if m % rows == 0 and rows <= target:
            best = rows
    return best


def batch_ops(ops: SolverOps, nbatch: int) -> SolverOps:
    """Per-member-unrolled batched bundle over an arbitrary SolverOps.

    Every op applies the wrapped bundle's exact unbatched subgraph to each
    member row and stacks — member i of the batched trajectory is
    bit-identical in f64 to a B=1 run through ``ops`` (a fused batched
    einsum or ``jax.vmap`` is *not*: XLA reassociates the contractions).
    Used for closure/non-Jacobi bundles and the Alg. 2 batched inner solves;
    the Jacobi problem bundles get genuinely batched kernels instead
    (``make_problem_ops(batch=...)``) with the same per-member guarantee."""
    import jax.numpy as jnp

    def member_dot(u, v):
        return (u @ v) if ops.dot is None else ops.dot(u, v)

    def matvec(p):
        return jnp.stack([ops.matvec(p[i]) for i in range(nbatch)])

    def matvec_dot(p):
        pairs = [ops.matvec_dot(p[i]) for i in range(nbatch)]
        return (jnp.stack([q for q, _ in pairs]),
                jnp.stack([d for _, d in pairs]))

    def precond(r):
        return jnp.stack([ops.precond(r[i]) for i in range(nbatch)])

    def update(alpha, x, r, p, q):
        outs = [ops.update(alpha[i], x[i], r[i], p[i], q[i])
                for i in range(nbatch)]
        return tuple(jnp.stack([o[j] for o in outs]) for j in range(4))

    def dot(u, v):
        return jnp.stack([member_dot(u[i], v[i]) for i in range(nbatch)])

    return SolverOps(ops.backend, matvec, matvec_dot, precond, update,
                     ops.variant, dot)


def make_problem_ops(problem, backend: str, batch: int = 0,
                     fused: bool = False) -> SolverOps:
    """SolverOps over a ``Problem``'s Block-ELL matrix and its registered
    preconditioner. backend: "jnp" | "pallas" | "interpret".

    Block-Jacobi (the default) keeps the fully fused update path — the
    preconditioner apply happens *inside* ``kernels/fused_pcg`` while r' is
    still in VMEM. Other preconditioners (SSOR, Chebyshev, IC(0)) cannot fuse
    into that kernel: the update is the x/r axpy pair + the preconditioner's
    own backend-routed apply + a plain rᵀz dot, written once in shared jnp so
    cross-backend bit-identity reduces to the apply's bit-identity (tested
    per preconditioner in tests/test_precond.py).

    ``batch`` > 0 builds the batched bundle: every op takes/returns a leading
    B axis ((B, M) vectors, (B,) scalars) and one dispatch advances all B
    members. Jacobi routes through the genuinely batched kernels (leading-B
    grid dim / per-member-unrolled refs), so member i stays bit-identical in
    f64 to its B=1 run on the same backend; other preconditioners fall back
    to the generic per-member wrapper ``batch_ops``.

    ``fused=True`` (batched Jacobi only) swaps the jnp hot-loop ops for the
    fused-batched einsum variants — one op serves all B members, which is
    what amortizes the batch on an op-overhead-bound host backend — at the
    price of per-member rounding no longer being bit-identical to the B=1
    run (~ulp deviation; convergence unaffected). The serving path opts in;
    the default stays exact."""
    from repro.kernels.fused_pcg.fused_pcg import fused_pcg_update
    from repro.kernels.fused_pcg.ref import fused_pcg_update_ref
    from repro.kernels.spmv.ref import spmv_dot_ref, spmv_seq_ref
    from repro.kernels.spmv.spmv import spmv, spmv_dot

    a = problem.a
    pinv = problem.pinv_blocks
    rows = pick_rows(problem.m, problem.precond_block)
    jacobi = problem.precond is None or problem.precond.name == "jacobi"

    if batch:
        if not jacobi:
            # non-jacobi batched bundles stay per-member-unrolled even under
            # fused: the sweep/polynomial applies have no fused-batched form
            return batch_ops(make_problem_ops(problem, backend), batch)
        return _make_batched_jacobi_ops(problem, backend, batch, rows, fused)

    if backend == "jnp":
        def matvec(x):
            return spmv_seq_ref(a.data, a.idx, x)

        def matvec_dot(x):
            return spmv_dot_ref(a.data, a.idx, x)

        if jacobi:
            def update(alpha, x, r, p, q):
                return fused_pcg_update_ref(alpha, x, r, p, q, pinv,
                                            rows=rows)
    elif backend in ("pallas", "interpret"):
        interp = backend == "interpret"

        def matvec(x):
            return spmv(a.data, a.idx, x, interpret=interp)

        def matvec_dot(x):
            return spmv_dot(a.data, a.idx, x, interpret=interp)

        if jacobi:
            def update(alpha, x, r, p, q):
                return fused_pcg_update(alpha, x, r, p, q, pinv, rows=rows,
                                        interpret=interp)
    else:
        raise ValueError(f"unknown SolverOps backend {backend!r}")

    if jacobi:
        # seed behaviour: the bundle's standalone precond is the jnp einsum
        # for every backend (used only off the hot path: esrp_init, residual
        # replacement) — keeps cross-backend trajectories bit-identical.
        precond = problem.apply_precond
    else:
        precond = problem.precond.make_apply(backend)

        def update(alpha, x, r, p, q, _precond=precond):
            import jax

            x_new = x + alpha * p
            # barriers: materialize r' before the apply and z' after it, so
            # XLA cannot fuse the axpy / the rᵀz dot into the jnp backend's
            # apply internals (fusions the opaque Pallas calls never get) —
            # keeps the backends bit-identical in f64
            r_new = jax.lax.optimization_barrier(r - alpha * q)
            z_new = jax.lax.optimization_barrier(_precond(r_new))
            return x_new, r_new, z_new, r_new @ z_new

    return SolverOps(backend, matvec, matvec_dot, precond, update)


def _make_batched_jacobi_ops(problem, backend: str, batch: int,
                             rows: int, fused: bool = False) -> SolverOps:
    """Batched Jacobi bundle: hot-loop ops are single batched kernel calls
    (one dispatch for B members); the off-hot-loop precond/dot are
    per-member unrolled so every op keeps the per-member f64 bit-identity
    with the unbatched backend.

    ``fused=True`` routes the jnp hot loop through the fused-batched einsum
    refs instead (one op per iteration for the whole batch — the
    throughput mode; see kernels/spmv/ref.py) and batches precond/dot the
    same way. The Pallas/interpret kernels are already one dispatch per
    batch either way."""
    import jax.numpy as jnp

    from repro.kernels.fused_pcg.fused_pcg import fused_pcg_update_batched
    from repro.kernels.fused_pcg.ref import (fused_pcg_update_ref_batched,
                                             fused_pcg_update_ref_fused)
    from repro.kernels.spmv.ref import (spmv_dot_ref_batched,
                                        spmv_dot_ref_fused,
                                        spmv_seq_ref_batched,
                                        spmv_seq_ref_fused)
    from repro.kernels.spmv.spmv import spmv_batched, spmv_dot_batched

    a = problem.a
    pinv = problem.pinv_blocks

    if backend == "jnp" and fused:
        def matvec(x):
            return spmv_seq_ref_fused(a.data, a.idx, x)

        def matvec_dot(x):
            return spmv_dot_ref_fused(a.data, a.idx, x)

        def update(alpha, x, r, p, q):
            return fused_pcg_update_ref_fused(alpha, x, r, p, q, pinv,
                                              rows=rows)
    elif backend == "jnp":
        def matvec(x):
            return spmv_seq_ref_batched(a.data, a.idx, x)

        def matvec_dot(x):
            return spmv_dot_ref_batched(a.data, a.idx, x)

        def update(alpha, x, r, p, q):
            return fused_pcg_update_ref_batched(alpha, x, r, p, q, pinv,
                                                rows=rows)
    elif backend in ("pallas", "interpret"):
        interp = backend == "interpret"

        def matvec(x):
            return spmv_batched(a.data, a.idx, x, interpret=interp)

        def matvec_dot(x):
            return spmv_dot_batched(a.data, a.idx, x, interpret=interp)

        def update(alpha, x, r, p, q):
            return fused_pcg_update_batched(alpha, x, r, p, q, pinv,
                                            rows=rows, interpret=interp)
    else:
        raise ValueError(f"unknown SolverOps backend {backend!r}")

    if fused:
        nb, blk, _ = pinv.shape

        def precond(r):
            return jnp.einsum("nij,bnj->bni", pinv,
                              r.reshape(batch, nb, blk)).reshape(batch, -1)

        def dot(u, v):
            return jnp.einsum("bi,bi->b", u, v)
    else:
        def precond(r):
            return jnp.stack([problem.apply_precond(r[i])
                              for i in range(batch)])

        def dot(u, v):
            return jnp.stack([u[i] @ v[i] for i in range(batch)])

    return SolverOps(backend, matvec, matvec_dot, precond, update, dot=dot)
