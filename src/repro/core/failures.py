"""Node-failure simulation (paper §4) and failure scenarios.

A node failure zeroes *all dynamic data* owned by the failed nodes (their
entries of x, r, z, p, the starred locals, and their replicated scalars) —
exactly the paper's simulation protocol: "the nodes set to fail zero-out all
their vector entries, as well as the scalars they contain". Static data
(matrix, preconditioner, b) is reloadable from safe storage and is never
touched. The failed nodes also act as their own replacements (paper §4).

A *scenario* generalizes the paper's single injected event to a list of
``FailureEvent(iter, nodes)`` entries — simultaneous multi-node failures
(several nodes in one event, the case Pachajoa et al. arXiv:1907.13077
study systematically) and staggered multi-event runs (failure → recover →
fail again, including a second event striking before the next completed
storage stage). Events fire once each, when the driver's iteration counter
first reaches ``iter`` after all earlier events fired; rollback rewinds the
counter but never re-arms a consumed event.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
import jax.numpy as jnp

from repro.sparse.partition import Partition


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One failure event: ``nodes`` fail simultaneously at iteration ``iter``
    (struck right after the (A)SpMV of that iteration, the paper's injection
    point).

    ``iter=0`` is valid: the event fires before any storage push completed,
    and the driver restarts cleanly (target_iter = -1). Negative iterations
    are rejected here, at construction, instead of surfacing later as a
    scenario-loop failure.
    """

    iter: int
    nodes: tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "nodes",
                           tuple(sorted(int(n) for n in self.nodes)))
        object.__setattr__(self, "iter", int(self.iter))
        if self.iter < 0:
            raise ValueError(
                f"{type(self).__name__} iteration must be >= 0, got "
                f"{self.iter} (iter=0 fires before the first storage push "
                f"and restarts; negative iterations can never fire)")


SDC_TARGETS = ("p", "r", "z", "x", "queue")
SDC_KINDS = ("bitflip", "perturb")


@dataclasses.dataclass(frozen=True)
class SDCEvent(FailureEvent):
    """Silent data corruption: at iteration ``iter`` (right after the
    (A)SpMV + storage prelude — the same mid-iteration point fail-stop
    events use), flip bits in / perturb the ``target`` shard owned by each
    node in ``nodes``. Nothing stops; the corrupted values silently
    propagate until an invariant check catches them.

    target: "p" | "r" | "z" | "x" — the live vector's entries on the listed
            nodes; "queue" — the newest redundancy-queue copy's entries on
            the listed nodes (on the mesh runtime: the physical ``rq`` rows
            the listed *holder* devices carry).
    kind:   "bitflip" — XOR bit ``bit`` of ``count`` entries per node
            (bit 62 = top exponent bit: a catastrophic, obvious flip;
            bit ~45 a subtle mantissa flip);
            "perturb" — add ``scale``·max|v| to those entries.
    """

    target: str = "p"
    kind: str = "bitflip"
    bit: int = 62          # bitflip: which of the 64 bits to XOR
    count: int = 1         # corrupted entries per listed node
    scale: float = 1e-3    # perturb: relative magnitude of the injection
    seed: int = 0          # deterministic in-slab entry choice

    def __post_init__(self):
        super().__post_init__()
        if self.target not in SDC_TARGETS:
            raise ValueError(f"SDCEvent target must be one of {SDC_TARGETS},"
                             f" got {self.target!r}")
        if self.kind not in SDC_KINDS:
            raise ValueError(f"SDCEvent kind must be one of {SDC_KINDS}, "
                             f"got {self.kind!r}")
        if not 0 <= self.bit < 64:
            raise ValueError(f"SDCEvent bit must be in [0, 64), "
                             f"got {self.bit}")
        if self.count < 1:
            raise ValueError(f"SDCEvent count must be >= 1, got {self.count}")


def normalize_scenario(
        scenario: Optional[Sequence["FailureEvent"]],
        fail_at: Optional[int],
        failed_nodes: Optional[Sequence[int]],
        n_nodes: int) -> list["FailureEvent"]:
    """Merge the legacy single-event API into the scenario form and validate.

    ``fail_at``/``failed_nodes`` remain the one-event shorthand; passing both
    a scenario and ``fail_at`` is ambiguous and rejected. Validation enforces
    the semantics the driver's scenario loop relies on: strictly increasing
    event iterations (each rollback target is below its own event, so later
    events always stay ahead of the rewound counter and fire exactly once),
    at least one surviving node per event, and in-range node ids.
    """
    if scenario is not None and (fail_at is not None
                                 or failed_nodes is not None):
        raise ValueError(
            "pass either scenario=... or fail_at=.../failed_nodes=..., "
            "not both")
    if scenario is None:
        if fail_at is None:
            if failed_nodes is not None:
                # silently returning [] here would drop the caller's
                # requested failure and report a clean solve — the run would
                # measure nothing
                raise ValueError(
                    f"failed_nodes={list(failed_nodes)} was passed without "
                    f"fail_at: no iteration to inject the failure at (pass "
                    f"fail_at=<iter> or a scenario=[FailureEvent(...)])")
            return []
        scenario = [FailureEvent(fail_at, tuple(failed_nodes or [0]))]
    events = [ev if isinstance(ev, FailureEvent) else FailureEvent(*ev)
              for ev in scenario]
    prev = -1
    for ev in events:
        # iter >= 0 is already guaranteed by FailureEvent.__post_init__;
        # iter=0 (fires before any storage push — the driver restarts
        # cleanly) is a valid first event
        if ev.iter <= prev:
            raise ValueError(
                f"event iterations must be strictly increasing, "
                f"got {[e.iter for e in events]}")
        prev = ev.iter
        if not ev.nodes:
            raise ValueError(f"event at iter {ev.iter} has no failed nodes")
        if len(set(ev.nodes)) != len(ev.nodes):
            raise ValueError(
                f"event at iter {ev.iter} repeats nodes: {ev.nodes}")
        if any(n < 0 or n >= n_nodes for n in ev.nodes):
            raise ValueError(
                f"event at iter {ev.iter} names nodes outside "
                f"[0, {n_nodes}): {ev.nodes}")
        if len(ev.nodes) >= n_nodes and not isinstance(ev, SDCEvent):
            # an SDCEvent corrupts data but kills nobody: striking every
            # node is meaningful (repair rolls back to the surviving
            # stars/queue); a fail-stop of every node has no survivors
            raise ValueError(
                f"event at iter {ev.iter} fails all {n_nodes} nodes — "
                f"no survivors to reconstruct from")
    return events


def failed_row_mask(part: Partition, failed: list[int]) -> np.ndarray:
    """(M,) bool — True on indices I_f owned by the failed nodes."""
    mask = np.zeros(part.m, bool)
    for s in failed:
        lo, hi = part.node_rows(s)
        mask[lo:hi] = True
    return mask


def failed_rows(part: Partition, failed: list[int]) -> np.ndarray:
    """Concatenated (sorted) global row indices I_f."""
    return np.concatenate([np.arange(*part.node_rows(s)) for s in sorted(failed)])


def compact_map(part: Partition, failed: list[int]):
    """Map global indices in I_f -> compact [0, |I_f|) (for A_ff assembly)."""
    failed = sorted(failed)
    starts = np.array([part.node_rows(s)[0] for s in failed])
    r = part.rows_per_node

    def to_compact(idx: np.ndarray) -> np.ndarray:
        node_pos = np.searchsorted(starts, idx, side="right") - 1
        return node_pos * r + (idx - starts[node_pos])

    return to_compact


def zero_failed(vec: jnp.ndarray, mask: np.ndarray) -> jnp.ndarray:
    """Lose the failed nodes' entries of a distributed vector."""
    return jnp.where(jnp.asarray(mask), jnp.zeros_like(vec), vec)
