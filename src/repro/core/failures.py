"""Node-failure simulation (paper §4).

A node failure zeroes *all dynamic data* owned by the failed nodes (their
entries of x, r, z, p, the starred locals, and their replicated scalars) —
exactly the paper's simulation protocol: "the nodes set to fail zero-out all
their vector entries, as well as the scalars they contain". Static data
(matrix, preconditioner, b) is reloadable from safe storage and is never
touched. The failed nodes also act as their own replacements (paper §4).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.sparse.partition import Partition


def failed_row_mask(part: Partition, failed: list[int]) -> np.ndarray:
    """(M,) bool — True on indices I_f owned by the failed nodes."""
    mask = np.zeros(part.m, bool)
    for s in failed:
        lo, hi = part.node_rows(s)
        mask[lo:hi] = True
    return mask


def failed_rows(part: Partition, failed: list[int]) -> np.ndarray:
    """Concatenated (sorted) global row indices I_f."""
    return np.concatenate([np.arange(*part.node_rows(s)) for s in sorted(failed)])


def compact_map(part: Partition, failed: list[int]):
    """Map global indices in I_f -> compact [0, |I_f|) (for A_ff assembly)."""
    failed = sorted(failed)
    starts = np.array([part.node_rows(s)[0] for s in failed])
    r = part.rows_per_node

    def to_compact(idx: np.ndarray) -> np.ndarray:
        node_pos = np.searchsorted(starts, idx, side="right") - 1
        return node_pos * r + (idx - starts[node_pos])

    return to_compact


def zero_failed(vec: jnp.ndarray, mask: np.ndarray) -> jnp.ndarray:
    """Lose the failed nodes' entries of a distributed vector."""
    return jnp.where(jnp.asarray(mask), jnp.zeros_like(vec), vec)
