#!/usr/bin/env python
"""Calibrate the StorageTier bandwidth/latency constants on this machine.

    PYTHONPATH=src python scripts/calibrate_tiers.py \
        [--out artifacts/calibration/tiers.json] [--size-mb 64] [--reps 5]

Measures the real transfer paths the tier cost models stand in for:

  * device<->device copy bandwidth + small-transfer latency floor — the
    ``device-neighbour`` tier (the ppermute-class path; on a CPU-only
    runner this is a memory copy, which is exactly what the "interconnect"
    is on that topology);
  * host->device (write/push) and device->host (read/fetch) bandwidth +
    latency — the ``replicated-host`` tier (jax.device_put / host readback
    over whatever link the runner has);
  * ``simulated-nvram`` — not measurable without the part: DERIVED from the
    measured host numbers with the persistent-memory asymmetry ratios the
    placeholder encoded (read = host/2, write = host/6, latency floor
    1e-4 s), and labeled as derived in its provenance.

The record is written as JSON; point ``REPRO_TIER_CALIBRATION`` at it and
``repro.core.tiers`` swaps the placeholder constants for the measured ones
at import time, with the provenance riding into every ``BENCH_*.json`` tier
section. CI's bench-smoke runs this on the runner so recorded sweeps state
their real calibration instead of class numbers.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import socket
import time


def _bandwidth_gbps(nbytes: int, seconds: float) -> float:
    return nbytes / seconds / 1e9


def measure(size_mb: int = 64, reps: int = 5) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    n = size_mb * (1 << 20) // 8
    host = np.random.default_rng(0).standard_normal(n)     # f64
    nbytes = host.nbytes
    dev = jax.devices()[0]

    def best(fn, *, warm=1):
        for _ in range(warm):
            fn()
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return min(ts)                    # min = least-noise estimate

    # host -> device (the replicated-host push direction)
    h2d = best(lambda: jax.device_put(host, dev).block_until_ready())
    # device -> host (the recovery fetch direction); np.array(copy=True)
    # because on a CPU backend np.asarray aliases the device buffer and
    # would "measure" a zero-copy view at TB/s
    darr = jax.device_put(host, dev)
    darr.block_until_ready()
    d2h = best(lambda: np.array(darr))
    # device -> device copy (the neighbour/interconnect class)
    d2d = best(lambda: jnp.copy(darr).block_until_ready())

    # latency floors from ~1 KB transfers (bandwidth term negligible)
    tiny_h = np.ones(128)
    tiny_d = jax.device_put(tiny_h, dev)
    tiny_d.block_until_ready()
    lat_h2d = best(lambda: jax.device_put(tiny_h, dev).block_until_ready(),
                   warm=3)
    lat_d2h = best(lambda: np.array(tiny_d), warm=3)
    lat_dev = best(lambda: jnp.copy(tiny_d).block_until_ready(), warm=3)

    host_read = _bandwidth_gbps(nbytes, d2h)
    host_write = _bandwidth_gbps(nbytes, h2d)
    prov = dict(host=socket.gethostname(), platform=platform.platform(),
                backend=jax.default_backend(), device=str(dev),
                date=time.strftime("%Y-%m-%d"), size_mb=size_mb, reps=reps)
    tag = (f"measured host={prov['host']} backend={prov['backend']} "
           f"date={prov['date']}")
    return dict(
        provenance=prov,
        raw=dict(nbytes=nbytes, h2d_s=h2d, d2h_s=d2h, d2d_s=d2d,
                 lat_h2d_s=lat_h2d, lat_d2h_s=lat_d2h, lat_dev_s=lat_dev),
        tiers={
            "device-neighbour": dict(
                read_gbps=_bandwidth_gbps(nbytes, d2d),
                write_gbps=_bandwidth_gbps(nbytes, d2d),
                latency_s=lat_dev, provenance=tag),
            "replicated-host": dict(
                read_gbps=host_read, write_gbps=host_write,
                latency_s=max(lat_h2d, lat_d2h), provenance=tag),
            "simulated-nvram": dict(
                read_gbps=host_read / 2.0, write_gbps=host_write / 6.0,
                latency_s=max(1e-4, lat_h2d),
                provenance=tag + " (derived: host/2 read, host/6 write, "
                                 "1e-4 s floor)"),
        })


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="artifacts/calibration/tiers.json")
    ap.add_argument("--size-mb", type=int, default=64)
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args()

    doc = measure(args.size_mb, args.reps)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    for name, rec in doc["tiers"].items():
        print(f"{name}: read {rec['read_gbps']:.1f} GB/s, "
              f"write {rec['write_gbps']:.1f} GB/s, "
              f"latency {rec['latency_s'] * 1e6:.1f} us")
    print(f"# wrote {args.out} — export REPRO_TIER_CALIBRATION={args.out} "
          f"to use it")


if __name__ == "__main__":
    main()
