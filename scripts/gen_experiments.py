"""Assemble EXPERIMENTS.md from dry-run artifacts + benchmark CSVs.

Usage: PYTHONPATH=src python scripts/gen_experiments.py
Reads artifacts/dryrun (optimized), artifacts/dryrun_baseline (paper-faithful
baseline), artifacts/bench/*.csv. The §Perf iteration log is maintained here.
"""
import glob
import json
import os

PEAK, HBM, LINK = 197e12, 819e9, 50e9


def load(d):
    out = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def terms(rec):
    pd = rec["per_device"]
    c, m, n = pd["hlo_flops"] / PEAK, pd["hbm_bytes"] / HBM, \
        pd["collective_bytes"] / LINK
    dom = max((("compute", c), ("memory", m), ("collective", n)),
              key=lambda t: t[1])
    return c, m, n, dom[0], (c / max(c, m, n) if max(c, m, n) else 0), \
        rec["model_flops_per_device"] / max(pd["hlo_flops"], 1)


def roofline_table(cur, mesh):
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | roofline frac | useful |",
            "|---|---|---:|---:|---:|---|---:|---:|"]
    for key in sorted(cur):
        if key[2] != mesh:
            continue
        rec = cur[key]
        if rec.get("status") != "ok":
            rows.append(f"| {key[0]} | {key[1]} | FAILED | | | | | |")
            continue
        c, m, n, dom, frac, useful = terms(rec)
        rows.append(f"| {key[0]} | {key[1]} | {c:.3f} | {m:.3f} | {n:.3f} "
                    f"| {dom} | {frac:.3f} | {useful:.2f} |")
    return "\n".join(rows)


def baseline_vs_now(cur, base):
    rows = ["| arch | shape | baseline max-term s | optimized max-term s | "
            "speedup | baseline frac | optimized frac |",
            "|---|---|---:|---:|---:|---:|---:|"]
    for key in sorted(cur):
        if key[2] != "16x16":
            continue
        a, b = cur.get(key), base.get(key)
        if not a or not b or a.get("status") != "ok" or \
                b.get("status") != "ok":
            continue
        ca, ma, na, _, fa, _ = terms(a)
        cb, mb, nb, _, fb, _ = terms(b)
        mx_a, mx_b = max(ca, ma, na), max(cb, mb, nb)
        if abs(mx_b - mx_a) / max(mx_b, 1e-12) < 0.01:
            continue                       # unchanged cells omitted
        rows.append(f"| {key[0]} | {key[1]} | {mx_b:.3f} | {mx_a:.3f} | "
                    f"{mx_b / mx_a:.1f}x | {fb:.3f} | {fa:.3f} |")
    return "\n".join(rows)


def dryrun_summary(cur):
    ok = sum(1 for r in cur.values() if r.get("status") == "ok")
    fail = len(cur) - ok
    per_mesh = {}
    for (a, s, m), r in cur.items():
        per_mesh.setdefault(m, [0, 0])
        per_mesh[m][0 if r.get("status") == "ok" else 1] += 1
    lines = [f"- {ok} / {len(cur)} cells compile ({fail} failures)."]
    for m, (o, f) in sorted(per_mesh.items()):
        lines.append(f"  - mesh {m}: {o} ok, {f} failed")
    # compile times
    ts = [r["t_compile_s"] for r in cur.values() if r.get("status") == "ok"]
    lines.append(f"- compile time per cell: median "
                 f"{sorted(ts)[len(ts)//2]:.1f}s, max {max(ts):.1f}s "
                 f"(1-core CPU host; lower+compile with 512 partitions).")
    return "\n".join(lines)


PERF_LOG = """\
### Cell 1 — qwen2-moe-a2.7b × train_4k (worst baseline roofline fraction, 0.016)

| iter | hypothesis (napkin math) | change | dominant term before → after | verdict |
|---|---|---|---|---|
| moe-1 | the dispatch scatter uses *global* token indices, so GSPMD cannot shard the (E·C, d) buffer and replicates + all-reduces it per layer: buf = 64·81920·2048·2B ≈ 21.5 GB, ×(fwd+bwd grads) ≈ the measured 6.7e12 B/step of all-reduce | group-local dispatch bound to the dp axis (G=16 groups, sort/scatter indices local per group; expert GEMMs on (G,E,C,d), G→dp, E→tp) | N 138.86 s → 11.43 s (12.2×); C 2.16→0.65; useful 0.16→0.52 | **confirmed** |
| moe-2 | remaining 103 GB/step all-gather = shared-expert branch on a (1, n, d) pseudo-batch (size-1 batch dim unshardable → 1M-token activations replicate); + 51 GB/step u32 all-reduce = take_along_axis broadcasting indices to (G, ng·k, d) | shared experts on the natural (B,S,d) layout; vmapped integer gathers | N 11.43 → 8.54 s; AG 1.96e11→5.7e10; useful 0.52→0.70 | **confirmed** |
| moe-3 | attention weights are tiny (16 M/layer) — replicating them and running attention data-parallel should remove the ~1 GB/layer Megatron ARs | new "ep" profile: model axis reserved for experts, attention/dense-MLP on a separate (unbound) "atp" logical axis | N 8.54 → 8.93 s, C 0.48 → 0.95 s — **worse**: per-device S² attention compute rises 16× while the removed ARs were only ~half the remaining traffic | **refuted** — reverted to 2d (the profile machinery stays; useful for attention-light MoEs) |
| moe-4 | slot-sharding the combine over the model axis should turn the masked all-reduce (≈ 1.07 GB/layer) into per-pair a2a traffic (≈ 67 MB) | constrain slot_out to ("dp","seqtp",None) | N 8.54 → 12.70 s — GSPMD all-gathers the expert buffer instead of forming an a2a | **refuted** — reverted. The true fix is a shard_map-level manual all_to_all (outside GSPMD's pattern library); two consecutive <5% iterations ⇒ stop rule for this cell |

**Result: dominant term 138.86 s → 8.54 s (16.3×).** granite-moe shares the
same code path and improves collaterally (see before/after table).

### Cell 2 — xlstm-125m × prefill_32k (most collective-bound, N = 8 721 s)

| iter | hypothesis | change | before → after | verdict |
|---|---|---|---|---|
| xlstm-1 | the mLSTM lax.scan runs 32 768 sequential steps; per-step input resharding makes GSPMD emit ~13.3 GB of all-gather *per timestep* | exact stabilized **chunkwise mLSTM**: with in-chunk decay b_t = cumsum(log f) and a_j = i_j − b_j, the stabilizer unrolls to m_t = b_t + max(m_prev, cummax a_j), every weight exp(·) ≤ 1; S-step scan → S/64 chunk steps of (Q×Q)/(Q×P) MXU matmuls. Validated allclose (≤ 1e-4) vs the recurrent oracle, incl. carried state | N 8 720.6 → 1 694.9 s (5.1×); M 534 → 104 s | **confirmed** (partially — sLSTM scan remained) |
| xlstm-2 | remaining 2.6 GB/step all-gather: the Megatron-SP residual constraint propagates *sequence-over-model* sharding into the scan xs; a dynamic-slice over a sharded loop dim forces GSPMD to re-gather the full array every iteration | constrain every time-scanned input (sLSTM wx, mLSTM chunk tensors) to batch-only sharding; replicate the tiny recurrent R | N 1 694.9 → **0.370 s**; M 104 → 0.207 s | **confirmed** |

**Result: dominant term 8 720.6 s → 0.370 s (23 569×).** Remaining 0.37 s is
the sLSTM per-step scan's small gathers (inherent to its recurrent R h_{t-1}
term); a chunkwise sLSTM variant is the identified next lever.

### Cell 3 — command-r-plus-104b × train_4k (most representative: the dense
training cell the ESRP-for-training feature protects)

| iter | hypothesis | change | before → after | verdict |
|---|---|---|---|---|
| cr-1 | the 2D layout pays **both** Megatron-TP activation all-reduces (≈ 4 ARs × 1.6 GB × 2 = 13.5 GB/layer) **and** FSDP param gathers (≈ 12 GB/layer); pure ZeRO-3 pays only params: 3 bf16 gathers + grad sync ≈ 830 GB/device → ≈ 16.6 s | per-arch parallelism profile "fsdp": params/batch shard over all 256 chips, no TP | N 34.71 → 16.49 s; M 8.84 → 6.97; **roofline fraction 0.481 → 1.000** (compute-bound) | **confirmed** (napkin within 1%) |
| cr-2 | bf16 param *storage* should halve gather bytes | param_dtype = bfloat16 | N 16.49 → 16.49 s (unchanged) | **refuted** — XLA already hoists the compute-dtype casts above the gathers; they were bf16 all along. fp32 storage retained (optimizer quality) |

Residual analysis: the 415 GB "all-reduce" is grad sync measured at the
spmd-partitioning stage; TPU pipelines later fuse AR+dynamic-slice →
reduce-scatter, so the true N ≈ 12.5 s (our N is an upper bound). The
remaining compute gap (useful = 0.78) is causally-masked full-S² attention +
remat recompute — a flash-attention Pallas kernel is the next lever.
**Result: compute-bound at C = 16.70 s/step ⇒ model-FLOPs utilization ≈
0.78 × 197 TF = ~154 TF/chip (78% MFU) once collectives overlap.**

### Beyond the three required cells — cr-1 generalized

The cr-1 napkin math applies to every dense/hybrid/recurrent arch at these
sizes (TP activation ARs scale with B_loc·S·d; FSDP gathers with params —
for ≤ 30 B-param models at batch 256 × 4 k the params are far cheaper), so
the "fsdp" profile was applied to 7 more archs and re-measured
(single-pod train_4k, dominant-term seconds):

| arch | 2d baseline max-term s | fsdp | speedup | new bottleneck |
|---|---:|---:|---:|---|
| internlm2-1.8b | 2.88 | 0.37 | 7.7× | memory (frac 0.87) |
| glm4-9b | 15.85 | 1.56 | 10.1× | **compute (frac 1.00)** |
| gemma3-27b | 23.08 | 5.34 | 4.3× | collective (frac 0.94) |
| musicgen-medium | 17.20 | 0.98 | 17.5× | memory |
| internvl2-1b | 4.60 | 0.29 | 15.6× | memory |
| zamba2-7b | 10.81 | 2.40 | 4.5× | memory (frac 0.65) |
| xlstm-125m | 2774.6 | 1.19 | 2339× (with xlstm-1/2) | collective (sLSTM scan) |

(exact per-cell terms in the §Roofline table above, which reflects the
optimized profiles). MoE archs keep the 2d profile: their expert weights
need the model axis for expert parallelism — replicating 15 B expert params
does not fit HBM.

**Negative result / guard rail:** on the 2-pod mesh (512 chips) train_4k's
global batch (256) is *below* the device count; forcing ZeRO-3 there made
zamba2 13× worse (N 6.0 → 81.4 s: batch falls back to 32-way sharding while
params shard 512-way → resharding storm). The launcher therefore applies the
fsdp profile only when global_batch divides by the device count — at real
scale one raises the global batch (or microbatches) before widening ZeRO.

**Memory-term probe (zamba-1, refuted):** zamba2 train is memory-dominant
after the profile change (M = 2.40 s). Hypothesis: the SSD intra-chunk score
matmuls scale with chunk length Q, so Q 128→64 should cut M. Measured:
Q=64 → M 2.42 s, Q=256 → M 2.65 s — flat-to-worse: score-dot bytes (∝ S·Q)
fall exactly as inter-chunk state traffic (∝ S/Q · H·N·P) rises; Q = 128
already sits at the sweet spot. The remaining M is the FSDP weight-streaming
floor.
"""


def bench_tables():
    out = []
    for t in ("table2", "table3", "table4"):
        f = f"artifacts/bench/{t}.csv"
        if os.path.exists(f):
            out.append(f"### {t}\n\n```\n{open(f).read().strip()}\n```")
    return "\n\n".join(out)


def main():
    cur = load("artifacts/dryrun")
    base = load("artifacts/dryrun_baseline")
    doc = f"""# EXPERIMENTS

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
All per-chip terms come from the while-aware analyzer over the
post-SPMD-partitioning HLO (see `repro/roofline/hlo_analysis.py` for the
exact cost model and DESIGN.md §9 for why that dump is the faithful source
on a CPU host). MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (serve);
"useful" = MODEL_FLOPS / HLO_FLOPs. Roofline fraction = compute term /
dominant term (1.0 = compute-bound).

## §Dry-run

Every (architecture × applicable shape) cell lowers AND compiles on the
single-pod 16×16 mesh and the 2-pod 2×16×16 mesh (deliverable e). 33 cells ×
2 meshes = 66 compilations; 7 long_500k cells are skipped by design for pure
full-attention archs (DESIGN.md §Arch-applicability).

{dryrun_summary(cur)}

Per-cell artifacts (memory_analysis, cost_analysis, collective breakdown,
while-loop trip counts) live in `artifacts/dryrun/*.json`; the paper-faithful
baseline snapshot (pre-§Perf) is `artifacts/dryrun_baseline/`.

## §Roofline — single-pod (16×16, 256 chips), optimized configuration

{roofline_table(cur, "16x16")}

### Multi-pod (2×16×16, 512 chips)

{roofline_table(cur, "2x16x16")}

Reading guide: decode cells are tiny-absolute-time and memory/collective
bound by nature (one token vs a 32k-500k cache — expected). The long_500k
rows exist only for the sub-quadratic archs. "useful" below ~0.5 on serve
cells reflects cache-wide masked ops vs the 2·N·B model-FLOPs convention;
on MoE cells it additionally reflects capacity-factor padding (×1.25).

## §Perf — baseline all cells, hillclimb three (hypothesis → change → measure)

The paper-faithful implementation was lowered for every cell first
(`artifacts/dryrun_baseline/`). Three cells were then hillclimbed per the
required selection rule — worst roofline fraction (qwen2-moe train_4k,
frac 0.016), most collective-bound (xlstm prefill_32k, N = 8 721 s), most
representative of the technique (command-r-plus train_4k — the dense
training workload ESRP protects):

{PERF_LOG}

### Collateral improvements (all changed cells, baseline → optimized)

{baseline_vs_now(cur, base)}

## §Solver benchmarks — the paper's tables (CPU host, 16 simulated nodes)

Protocol = paper §5: medians of ≥5 repetitions, failure 2 iterations before
the end of the interval containing C/2 (worst case), locations start/center,
ψ = φ simultaneous failures, rtol 1e-8, inner reconstruction solves at 1e-14.
SuiteSparse is unavailable offline; seeded surrogates of the same regime are
used (DESIGN.md §3). Notes vs the paper: (i) wall times are 1-core CPU
simulations — *relative* overheads are the meaningful signal, and they are
noisier than the paper's 128-node medians (the paper itself reports
noise-limited cases); (ii) reconstruction overhead is a larger *fraction*
here because the surrogate problems converge in under a second while the
inner solve cost does not shrink proportionally (the paper's runs are
15-23 s) — the paper's own observation that recovery cost depends on the
matrix and failed-block location reproduces cleanly; (iii) ESRP failure-free
overhead decreasing with T, and ESR (T=1) being the most expensive
failure-free variant at high φ, both reproduce.

{bench_tables()}

### Communication-volume model (exact; paper §2.2.1 / §3.1)

`python -m benchmarks.run --only volume` prints, per matrix and φ: natural
SpMV bytes, augmented ASpMV bytes, per-stage ESRP extra bytes
(2 augmented products), and the IMCR checkpoint bytes (4 vectors × φ
buddies). This is the scale-relevant comparison the paper argues
qualitatively: ESRP's redundancy rides existing communication; IMCR's is a
new round. For the training-side analogue, `--only ft` reports
ESRP ≈ 2/3 of IMCR push volume (moments only vs params+moments), and bf16
moment compression halves it again (beyond-paper).

## ESRP-for-training validation

`tests/test_ft_trainer.py`: after a simulated ≤ φ node failure, recovery +
deterministic replay reproduces the undisturbed run **bit-exactly** (the
paper's trajectory-identity property carried to Adam training), for ESRP and
IMCR, with buddy buffers hosted on failed nodes also lost (paper §4
semantics). Compressed (bf16) redundancy gives a bounded ~1e-4 deviation.
Elastic restart (checkpoint under 8 FSDP ranks, resume under 4, then another
failure) also reproduces the trajectory bit-exactly.

## Physical-runtime validation (multi-device)

`tests/test_solver_multidevice.py` + `tests/test_multidevice.py` (8 host
devices, subprocess):
- the sharded solver (block rows over a "nodes" mesh axis) reproduces the
  single-device ESRP solve iteration-for-iteration;
- `ring_halo_matvec` (±1 ``ppermute`` halo exchange — the paper's MPI
  neighbour sends on ICI) equals the reference SpMV to 1e-11;
- `aspmv_push` delivers every redundant tile of the ASpMV plan to its
  designated neighbour d_{{s,k}} via per-k ``collective-permute`` hops,
  verified value-by-value against the plan's holder matrix;
- the sharded LM train step matches the single-device step.

## Beyond-paper extensions (summary)

1. ESRP for LM training (params piggyback on FSDP gathers; moments buddy-
   pushed; rollback + deterministic replay) — DESIGN.md §4.
2. bf16-compressed redundancy pushes (half volume, bounded deviation).
3. Fused PCG-update Pallas kernel (one HBM pass for Alg. 1 lines 4-7).
4. Exact stabilized chunkwise mLSTM (23 569x on the xlstm prefill cell).
5. Grouped MoE dispatch (16.3x on the qwen2-moe train cell).
6. Per-arch parallelism profiles with a batch-divisibility guard rail
   (up to 17.5x on dense train cells; command-r to roofline fraction 1.0).
7. Residual replacement (r := b - Ax every K iters): tightens the paper's
   Eq. 2 drift and composes with ESRP recovery (tested).
8. Flash-attention Pallas kernel (causal + sliding-window, block skipping)
   — the identified next lever for the attention-bound cells.
9. Erratum fix for the paper's R^c_{{s,k}} condition (DESIGN.md §9).
"""
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print("EXPERIMENTS.md written",
          f"({sum(1 for r in cur.values() if r.get('status') == 'ok')} ok cells)")


if __name__ == "__main__":
    main()
