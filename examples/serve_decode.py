"""Serve a small model: batched prefill + greedy decode with KV caches.

    PYTHONPATH=src python examples/serve_decode.py --batch 4 --new-tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models.lm import LM
from repro.serve.serve_step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = LM(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens
    caches = model.init_cache(args.batch, max_len)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab,
        dtype=jnp.int32)

    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))
    tok, caches = prefill(params, {"tokens": prompts}, caches)
    out = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        tok, caches = decode(params, tok, caches, pos)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    seqs = jnp.concatenate(out, axis=1)
    print(f"{cfg.name}: {args.batch} seqs x {args.new_tokens} new tokens")
    print(f"{args.batch * (args.new_tokens - 1) / dt:.1f} tokens/s "
          f"(batched greedy, CPU)")
    print("sample:", list(map(int, seqs[0, :16])))


if __name__ == "__main__":
    main()
