"""End-to-end driver: the paper's experiment (§5) on a generated problem.

Runs the full resilient-solver matrix — reference, ESR (T=1), ESRP, IMCR —
with worst-case failure injection (2 iterations before the storage stage
containing iteration C/2), prints the Table-2-style overhead report, and
finishes with a staggered multi-event scenario (failure → recover → fail
again, φ nodes simultaneously in the first event) with the per-event
recovery breakdown.

    PYTHONPATH=src python examples/solve_poisson_resilient.py \
        --kind poisson3d --nx 32 --nodes 16 --T 20 --phi 3 --precond ssor
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

from repro.core.driver import solve_resilient
from repro.core.failures import FailureEvent
from repro.sparse.matrices import build_problem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="poisson3d",
                    choices=["poisson2d", "poisson3d", "banded"])
    ap.add_argument("--nx", type=int, default=32)
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--T", type=int, default=20)
    ap.add_argument("--phi", type=int, default=3)
    ap.add_argument("--rtol", type=float, default=1e-8)
    ap.add_argument("--precond", default="jacobi",
                    choices=["jacobi", "ssor", "chebyshev", "ic0"])
    args = ap.parse_args()

    kw = dict(nx=args.nx) if args.kind != "banded" else dict(
        n=args.nx ** 3, bandwidth=16)
    problem = build_problem(args.kind, n_nodes=args.nodes,
                            precond=args.precond, **kw)
    print(f"{args.kind} M={problem.m} on {args.nodes} nodes, "
          f"precond={args.precond}")

    ref = solve_resilient(problem, strategy="none", rtol=args.rtol)
    t0 = ref.runtime_s
    print(f"reference: C={ref.converged_iter}  t0={t0:.3f}s")
    fail_at = (ref.converged_iter // 2 // args.T) * args.T + args.T - 2
    failed = list(range(args.phi))

    print(f"\n{'strategy':10s} {'scenario':12s} {'time':>8s} {'overhead':>9s} "
          f"{'recon':>7s} {'wasted':>6s}")
    for strategy, T in (("esrp", 1), ("esrp", args.T), ("imcr", args.T)):
        label = "esr" if (strategy, T) == ("esrp", 1) else strategy
        r = solve_resilient(problem, strategy=strategy, T=T, phi=args.phi,
                            rtol=args.rtol)
        print(f"{label:10s} {'failure-free':12s} {r.runtime_s:8.3f} "
              f"{100 * (r.runtime_s - t0) / t0:8.1f}% {'-':>7s} {'-':>6s}")
        r = solve_resilient(problem, strategy=strategy, T=T, phi=args.phi,
                            rtol=args.rtol, fail_at=fail_at,
                            failed_nodes=failed)
        assert r.rel_residual < args.rtol
        print(f"{label:10s} {'w/ failures':12s} {r.runtime_s:8.3f} "
              f"{100 * (r.runtime_s - t0) / t0:8.1f}% "
              f"{r.recovery_s:6.3f}s {r.wasted_iters:6d}")

    # staggered multi-event scenario: phi nodes at once, recover, then a
    # second single-node failure a period later
    scenario = [FailureEvent(fail_at, tuple(failed)),
                FailureEvent(fail_at + args.T, ((args.phi + 1) % args.nodes,))]
    r = solve_resilient(problem, strategy="esrp", T=args.T, phi=args.phi,
                        rtol=args.rtol, scenario=scenario)
    assert r.rel_residual < args.rtol
    print(f"\nstaggered scenario ({len(scenario)} events), C="
          f"{r.converged_iter}, overhead {100 * (r.runtime_s - t0) / t0:.1f}%:")
    for e in r.events:
        print(f"  iter {e.iter:4d} nodes {e.nodes}: rollback -> "
              f"{e.target_iter} ({e.wasted_iters} wasted, "
              f"{1e3 * e.recovery_s:.1f} ms reconstruction)")


if __name__ == "__main__":
    main()
