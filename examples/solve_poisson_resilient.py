"""End-to-end driver: the paper's experiment (§5) on a generated problem.

Runs the full resilient-solver matrix — reference, ESR (T=1), ESRP, IMCR —
with worst-case failure injection (2 iterations before the storage stage
containing iteration C/2), prints the Table-2-style overhead report, and
finishes with a staggered multi-event scenario (failure → recover → fail
again, φ nodes simultaneously in the first event) with the per-event
recovery breakdown.

With ``--sdc`` an extra section injects silent data corruption (a bit flip
in the search direction p, and a perturbed redundancy-queue copy) instead
of a fail-stop: the invariant checks detect the corruption within one
check period, route it through the same Alg. 2 reconstruction, and the run
rejoins the clean trajectory — the report prints which detector fired, the
detection latency, and the distance to the corruption-free solution.

    PYTHONPATH=src python examples/solve_poisson_resilient.py \
        --kind poisson3d --nx 32 --nodes 16 --T 20 --phi 3 --precond ssor \
        --sdc
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core.driver import solve_resilient
from repro.core.failures import FailureEvent, SDCEvent
from repro.sparse.matrices import build_problem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="poisson3d",
                    choices=["poisson2d", "poisson3d", "banded"])
    ap.add_argument("--nx", type=int, default=32)
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--T", type=int, default=20)
    ap.add_argument("--phi", type=int, default=3)
    ap.add_argument("--rtol", type=float, default=1e-8)
    ap.add_argument("--precond", default="jacobi",
                    choices=["jacobi", "ssor", "chebyshev", "ic0"])
    ap.add_argument("--sdc", action="store_true",
                    help="also inject silent data corruption (bit flip in "
                         "p, perturbed queue copy) and show detection + "
                         "repair via the invariant checks")
    ap.add_argument("--trace", action="store_true",
                    help="thread the telemetry tracer through the staggered "
                         "scenario solve; prints the per-phase wall-time "
                         "breakdown and writes "
                         "artifacts/obs/poisson_resilient_trace.json")
    args = ap.parse_args()

    kw = dict(nx=args.nx) if args.kind != "banded" else dict(
        n=args.nx ** 3, bandwidth=16)
    problem = build_problem(args.kind, n_nodes=args.nodes,
                            precond=args.precond, **kw)
    print(f"{args.kind} M={problem.m} on {args.nodes} nodes, "
          f"precond={args.precond}")

    ref = solve_resilient(problem, strategy="none", rtol=args.rtol)
    t0 = ref.runtime_s
    print(f"reference: C={ref.converged_iter}  t0={t0:.3f}s")
    fail_at = (ref.converged_iter // 2 // args.T) * args.T + args.T - 2
    failed = list(range(args.phi))

    print(f"\n{'strategy':10s} {'scenario':12s} {'time':>8s} {'overhead':>9s} "
          f"{'recon':>7s} {'wasted':>6s}")
    for strategy, T in (("esrp", 1), ("esrp", args.T), ("imcr", args.T)):
        label = "esr" if (strategy, T) == ("esrp", 1) else strategy
        r = solve_resilient(problem, strategy=strategy, T=T, phi=args.phi,
                            rtol=args.rtol)
        print(f"{label:10s} {'failure-free':12s} {r.runtime_s:8.3f} "
              f"{100 * (r.runtime_s - t0) / t0:8.1f}% {'-':>7s} {'-':>6s}")
        r = solve_resilient(problem, strategy=strategy, T=T, phi=args.phi,
                            rtol=args.rtol, fail_at=fail_at,
                            failed_nodes=failed)
        assert r.rel_residual < args.rtol
        print(f"{label:10s} {'w/ failures':12s} {r.runtime_s:8.3f} "
              f"{100 * (r.runtime_s - t0) / t0:8.1f}% "
              f"{r.recovery_s:6.3f}s {r.wasted_iters:6d}")

    # staggered multi-event scenario: phi nodes at once, recover, then a
    # second single-node failure a period later
    scenario = [FailureEvent(fail_at, tuple(failed)),
                FailureEvent(fail_at + args.T, ((args.phi + 1) % args.nodes,))]
    r = solve_resilient(problem, strategy="esrp", T=args.T, phi=args.phi,
                        rtol=args.rtol, scenario=scenario, obs=args.trace)
    assert r.rel_residual < args.rtol
    print(f"\nstaggered scenario ({len(scenario)} events), C="
          f"{r.converged_iter}, overhead {100 * (r.runtime_s - t0) / t0:.1f}%:")
    for e in r.events:
        print(f"  iter {e.iter:4d} nodes {e.nodes}: rollback -> "
              f"{e.target_iter} ({e.wasted_iters} wasted, "
              f"{1e3 * e.recovery_s:.1f} ms reconstruction)")

    if args.trace:
        import os

        from repro.obs import span_tree, write_chrome_trace

        tr = r.trace
        os.makedirs("artifacts/obs", exist_ok=True)
        path = write_chrome_trace(
            tr, "artifacts/obs/poisson_resilient_trace.json")
        print(f"\nper-phase breakdown ({path}, {len(tr.events)} events, "
              f"push {tr.counters.get('tier_push_bytes', 0) / 1e6:.2f} MB / "
              f"fetch {tr.counters.get('tier_fetch_bytes', 0) / 1e3:.1f} KB):")

        def show(nodes, depth=0):
            # repeated phases (chunk dispatch/settle, resume) aggregate to
            # one line; each failure event expands to its full recovery tree
            agg, order = {}, []
            for n in nodes:
                if n["dur_us"] is None:
                    continue
                if n["name"].startswith("event:"):
                    order.append(("solo", n))
                    continue
                if n["name"] not in agg:
                    agg[n["name"]] = [n["cat"], 0, 0.0]
                    order.append(("agg", n["name"]))
                agg[n["name"]][1] += 1
                agg[n["name"]][2] += n["dur_us"]
            for kind, item in order:
                pad = "  " * depth
                if kind == "agg":
                    cat, calls, us = agg[item]
                    print(f"  {pad}{item:<30s}{us / 1e3:9.2f} ms  "
                          f"x{calls:<3d} [{cat}]")
                else:
                    print(f"  {pad}{item['name']:<30s}"
                          f"{item['dur_us'] / 1e3:9.2f} ms       "
                          f"[{item['cat']}]")
                    show(item["children"], depth + 1)

        roots = span_tree(tr.events)
        solve_root = roots[0] if roots else None
        if solve_root is not None:
            print(f"  {'solve':<30s}{solve_root['dur_us'] / 1e3:9.2f} ms"
                  f"       [{solve_root['cat']}]")
            show(solve_root["children"], 1)

    if args.sdc:
        xref = np.asarray(ref.x)
        xscale = max(float(np.linalg.norm(xref)), 1.0)
        print("\nsilent data corruption (detect + repair):")
        print(f"{'target':8s} {'kind':8s} {'detector':16s} {'inject':>6s} "
              f"{'caught':>6s} {'latency':>7s} {'wasted':>6s} "
              f"{'|x-xref|/|xref|':>15s}")
        for target, kind in (("p", "bitflip"), ("queue", "perturb")):
            r = solve_resilient(
                problem, strategy="esrp", T=args.T, phi=args.phi,
                rtol=args.rtol,
                scenario=[SDCEvent(iter=fail_at, nodes=(0,),
                                   target=target, kind=kind)])
            assert r.rel_residual < args.rtol
            reps = [e for e in r.events if e.kind == "sdc-repair"]
            assert len(reps) == 1, [e.kind for e in r.events]
            e = reps[0]
            err = float(np.linalg.norm(np.asarray(r.x) - xref)) / xscale
            print(f"{target:8s} {kind:8s} {e.detector:16s} {fail_at:6d} "
                  f"{e.detect_iter:6d} {e.detect_latency:7d} "
                  f"{e.wasted_iters:6d} {err:15.2e}")
        print("  (queue corruption costs zero wasted iterations: the copy "
              "is re-pushed\n   from live state without touching the "
              "iteration)")


if __name__ == "__main__":
    main()
