"""Train an LM with ESRP fault tolerance; kill nodes mid-run; recover.

Default is a CPU-sized model (so the example finishes in minutes); pass
``--arch <id> --steps N`` for the real configs on real hardware — the
trainer, FT layer, pipeline, and checkpointing are exactly the production
code paths.

    PYTHONPATH=src python examples/train_lm_esrp.py --steps 40 --fail-at 25
"""
import argparse
import time

import jax

from repro.configs import smoke_config, get_config
from repro.data.pipeline import TokenPipeline
from repro.ft import checkpoint
from repro.ft.esrp_trainer import ESRPTrainer, FTConfig
from repro.models.lm import LM
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (CPU-sized); --no-smoke for full")
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--T", type=int, default=10)
    ap.add_argument("--phi", type=int, default=1)
    ap.add_argument("--fail-at", type=int, default=25)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    print(f"{cfg.name}: {model.count_params(params) / 1e6:.1f}M params")
    opt = init_opt_state(params)
    step_fn = make_train_step(model, AdamWConfig(warmup_steps=20))
    pipe = TokenPipeline(cfg, global_batch=args.batch, seq_len=args.seq)

    trainer = ESRPTrainer(
        model, step_fn, pipe,
        FTConfig(mode="esrp", T=args.T, phi=args.phi, n_ranks=8), specs)
    t0 = time.time()
    params, opt, losses = trainer.run(
        params, opt, n_steps=args.steps, fail_at=args.fail_at,
        failed_ranks=list(range(args.phi)))
    dt = time.time() - t0
    ordered = sorted(losses)
    print(f"{args.steps} steps in {dt:.1f}s "
          f"({1000 * dt / args.steps:.0f} ms/step incl. recovery)")
    print(f"loss {losses[ordered[0]]:.4f} -> {losses[ordered[-1]]:.4f}")
    print(f"ESRP: {trainer.push_count} storage stages, "
          f"{trainer.push_bytes / 1e6:.1f} MB total moment pushes "
          f"(params rode the existing FSDP gather)")
    if args.ckpt_dir:
        checkpoint.save(args.ckpt_dir, args.steps, params=params, opt=opt)
        print(f"checkpoint at {args.ckpt_dir}/step_{args.steps:08d}")


if __name__ == "__main__":
    main()
