"""Streaming resilient solve service: many right-hand sides, one operator.

The production shape of the paper's setting: a PDE operator is built and
partitioned once, then a stream of load vectors arrives over time (time
steps, optimization iterates, parameter sweeps). ``SolverService`` drains
the request queue in fixed-width micro-batches through the batched
``solve_resilient`` — members that converge early freeze in place while
stragglers keep iterating, and a ``FailureEvent`` striking mid-batch is
repaired for all B members by ONE Alg. 2 reconstruction pass.

    PYTHONPATH=src python examples/serve_solver.py \
        --requests 24 --batch 8 --fail-at 30 --fail-every 2 --trace

``--exact`` switches the micro-batch from the fused throughput mode
(~ulp per-member deviation, where the aggregate-throughput win comes
from) to the exact per-member-unrolled bundle (bit-identical to B=1).
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core.failures import FailureEvent
from repro.serve.solver_service import SolverService
from repro.sparse.matrices import build_problem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="poisson2d",
                    choices=["poisson2d", "poisson3d", "banded"])
    ap.add_argument("--nx", type=int, default=28)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--strategy", default="esrp",
                    choices=["esrp", "imcr", "none"])
    ap.add_argument("--T", type=int, default=20)
    ap.add_argument("--rtol", type=float, default=1e-8)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject FailureEvent(iter, nodes) into every "
                         "fail-every'th micro-batch")
    ap.add_argument("--fail-nodes", default="1")
    ap.add_argument("--fail-every", type=int, default=2)
    ap.add_argument("--exact", action="store_true",
                    help="exact per-member bundle (bit-identical to B=1) "
                         "instead of the fused throughput mode")
    ap.add_argument("--trace", action="store_true",
                    help="record per-request latency spans; writes "
                         "artifacts/obs/serve_example_trace.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    kw = dict(nx=args.nx) if args.kind != "banded" else dict(
        n=args.nx ** 2, bandwidth=16)
    problem = build_problem(args.kind, n_nodes=args.nodes, **kw)
    scenario = None
    if args.fail_at is not None:
        nodes = tuple(int(s) for s in args.fail_nodes.split(","))
        scenario = [FailureEvent(args.fail_at, nodes)]

    svc = SolverService(problem, batch=args.batch, strategy=args.strategy,
                        T=args.T, rtol=args.rtol, scenario=scenario,
                        fail_every=args.fail_every, fused=not args.exact,
                        obs=args.trace)
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        svc.submit(rng.standard_normal(problem.part.m))

    print(f"{args.kind} M={problem.part.m} on {args.nodes} nodes | "
          f"{args.requests} requests, B={args.batch} "
          f"({'exact' if args.exact else 'fused'} mode)"
          + (f", failures@{args.fail_at} nodes={args.fail_nodes} every "
             f"{args.fail_every} micro-batches" if scenario else ""))
    results = svc.run()

    st = svc.stats()
    print(f"served {st['requests']} in {st['solve_wall_s']:.2f}s solve-wall "
          f"({st['throughput_rps']:.1f} req/s) | latency p50 "
          f"{st['latency_p50_ms']:.0f} ms p99 {st['latency_p99_ms']:.0f} ms "
          f"| {st['microbatches']} micro-batches, mean fill "
          f"{st['mean_fill']:.1f}, all_converged={st['all_converged']}")

    # per-request detail: placement, iterations, and any recovery events
    for r in results[:args.batch]:
        rep = r.report
        ev = (f", {len(rep.events)} failure event(s) -> recovered"
              if rep.events else "")
        print(f"  req {r.req_id}: batch {r.batch_seq}"
              f"[{rep.batch_index}/{rep.batch_size}] "
              f"iters={rep.converged_iter} rel={rep.rel_residual:.1e} "
              f"latency={r.latency_s * 1e3:.0f} ms{ev}")
    if len(results) > args.batch:
        print(f"  ... {len(results) - args.batch} more")

    if args.trace:
        import os
        from repro.obs import write_chrome_trace
        os.makedirs("artifacts/obs", exist_ok=True)
        path = write_chrome_trace(
            svc.tracer, "artifacts/obs/serve_example_trace.json")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
