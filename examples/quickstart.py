"""Quickstart: resilient PCG in ~20 lines (the paper in miniature).

Solves a 2-D Poisson system on 8 simulated nodes with ESRP (T=20, phi=2),
kills nodes 2 and 3 mid-solve, reconstructs exactly (Alg. 2), and converges
in the same number of iterations as an undisturbed run. ``--precond``
swaps the preconditioner (block-Jacobi, SSOR, Chebyshev, IC(0)) — the
non-block-diagonal ones exercise the recovery-aware P_{f,I\\f} / P_ff path.

    PYTHONPATH=src python examples/quickstart.py [--precond ssor]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

from repro.core.driver import solve_resilient
from repro.sparse.matrices import build_problem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--precond", default="jacobi",
                    choices=["jacobi", "ssor", "chebyshev", "ic0"])
    args = ap.parse_args()

    problem = build_problem("poisson2d", n_nodes=8, nx=64, ny=64,
                            precond=args.precond)
    print(f"problem: M={problem.m}, 8 nodes, "
          f"{args.precond}({problem.precond_block})")

    ref = solve_resilient(problem, strategy="none", rtol=1e-8)
    print(f"reference:       {ref.converged_iter} iters, "
          f"rel residual {ref.rel_residual:.2e}")

    rep = solve_resilient(
        problem, strategy="esrp", T=20, phi=2, rtol=1e-8,
        fail_at=ref.converged_iter // 2, failed_nodes=[2, 3])
    print(f"esrp w/ failure: {rep.converged_iter} iters, "
          f"rel residual {rep.rel_residual:.2e}")
    print(f"  rolled back to iteration {rep.target_iter} "
          f"({rep.wasted_iters} iterations replayed)")
    print(f"  reconstruction inner-solve residual {rep.inner_rel:.1e}")
    print(f"  residual drift (paper Eq. 2): {rep.drift:.2e} "
          f"(reference {ref.drift:.2e})")
    assert rep.converged_iter == ref.converged_iter
    print("exact state reconstruction: trajectory preserved ✓")


if __name__ == "__main__":
    main()
